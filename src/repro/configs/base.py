"""Config system: architecture configs and assigned input shapes.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG`` (the exact assigned full-size config) and ``SMOKE_CONFIG`` (a
reduced variant of the same family: <=2 layers, d_model<=512, <=4 experts)
used by CPU smoke tests.  Full configs are only exercised via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // num_heads

    # --- attention features ------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False               # qwen2-vl multimodal RoPE (3 sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # t/h/w splits of head_dim/2
    attn_logit_softcap: Optional[float] = None       # gemma2: 50.0
    final_logit_softcap: Optional[float] = None      # gemma2: 30.0
    sliding_window: Optional[int] = None             # local-attention window
    local_global_pattern: bool = False               # gemma2 alternating local/global
    # sliding-window variant used only for the long_500k shape on dense archs
    # (documented beyond-paper variant; gemma2 has local layers natively).
    long_context_window: Optional[int] = None
    attn_scale: Optional[float] = None               # default 1/sqrt(head_dim)

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # dispatch-group size: tokens are routed in contiguous groups of this
    # many tokens (shard-aligned).  §Perf lever: per-sequence groups are
    # pathological for decode (1-token groups pad capacity 128x).
    moe_group_size: int = 4096
    # mesh axis to pin the (G, E, C, d) dispatch buffer's expert dim to
    # (expert parallelism via explicit constraint).  §Perf lever: without
    # it XLA materializes an E-full buffer and all-reduces its gradient
    # over the model axis every layer.
    moe_buffer_shard: Optional[str] = None

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256              # SSD chunk length
    ssm_groups: int = 1               # B/C groups (a la GQA for SSM)

    # --- hybrid (zamba2): shared attention block every N mamba layers --------
    hybrid_attn_every: int = 6

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper: 30s audio -> 1500 frames (stub)

    # --- VLM (qwen2-vl): stub patch embeddings prepended ---------------------
    num_patches: int = 0

    # --- misc ----------------------------------------------------------------
    norm_eps: float = 1e-6
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm (whisper)
    act: str = "silu"                 # silu | gelu
    max_pos_embed: int = 0            # >0: learned position embeddings (whisper)
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma2 scales embeddings by sqrt(d_model)
    post_block_norm: bool = False     # gemma2 post-attn/post-ffn norms
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    # "nothing" = recompute everything in backward (min memory);
    # "dots" = save matmul outputs (no recompute of the big einsums —
    # trades HBM for the remat FLOPs, a §Perf lever for compute-bound pairs)
    remat_policy: str = "nothing"
    # scan_layers=False unrolls the layer loop (python loop over stacked
    # params) and attn_q_chunk=0 disables query chunking: used by the
    # roofline pass, because XLA cost_analysis counts a while-loop body
    # ONCE rather than x trip-count (see launch/dryrun.py).
    scan_layers: bool = True
    attn_q_chunk: int = 512
    # KV-cache storage: "bfloat16" (default) or "int8" (per-token/head
    # absmax quantization — §Perf lever: halves the decode memory term,
    # which dominates every decode pair in the roofline table)
    kv_cache_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (analytic; used for roofline MODEL_FLOPS = 6·N·D).
    def param_count(self, active_only: bool = False) -> int:
        d, h, kv = self.d_model, self.num_heads, self.num_kv_heads
        dh = self.resolved_head_dim if h else 0
        n = 0
        embed = self.vocab_size * d
        n += embed
        if not self.tie_embeddings:
            n += embed

        def attn_params() -> int:
            p = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
            if self.qkv_bias:
                p += (h + 2 * kv) * dh
            return p

        def mlp_params(dff: int) -> int:
            return 3 * d * dff          # gated (wi, wg, wo)

        for layer in range(self.num_layers):
            if self.family in ("dense", "vlm", "encdec"):
                n += attn_params() + mlp_params(self.d_ff) + 2 * d
            elif self.family == "moe":
                e = self.num_experts_per_tok if active_only else self.num_experts
                n += attn_params() + e * mlp_params(self.d_ff) + d * self.num_experts + 2 * d
            elif self.family == "ssm":
                di, ns = self.ssm_d_inner, self.ssm_state
                g = self.ssm_groups
                in_proj = d * (2 * di + 2 * g * ns + self.ssm_num_heads)
                conv = self.ssm_conv_width * (di + 2 * g * ns)
                out = di * d
                n += in_proj + conv + out + di + 2 * self.ssm_num_heads + d
            elif self.family == "hybrid":
                di, ns = self.ssm_d_inner, self.ssm_state
                g = self.ssm_groups
                in_proj = d * (2 * di + 2 * g * ns + self.ssm_num_heads)
                conv = self.ssm_conv_width * (di + 2 * g * ns)
                n += in_proj + conv + di * d + di + 2 * self.ssm_num_heads + d
        if self.family == "hybrid":
            # one weight-shared attention+MLP block (counted once)
            n += attn_params() + mlp_params(self.d_ff) + 2 * d
        if self.family == "encdec":
            for _ in range(self.encoder_layers):
                n += attn_params() + mlp_params(self.d_ff) + 2 * d
            # decoder cross-attention
            n += self.num_layers * attn_params()
        return n


# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def shape_skips(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """Return a reason string if (cfg, shape) is skipped, else None."""
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return ("enc-dec audio model: 500k-token decode has no audio analogue "
                    "and decoder is pure full attention (see DESIGN.md)")
        if cfg.family in ("dense", "vlm") and not (
            cfg.local_global_pattern or cfg.sliding_window or cfg.long_context_window
        ):
            return "pure full-attention arch without a sliding-window variant"
    return None


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins (no allocation) for dry-run lowering.

    train/prefill: the full batch of tokens (+ stub frontend embeddings for
    audio/vlm).  decode: ONE new token per sequence plus the KV/SSM cache of
    ``seq_len`` — see ``repro.models.transformer.init_cache_specs``.
    """
    from repro.models import transformer as T   # local import to avoid cycle

    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            specs["encoder_input"] = _sds((B, cfg.encoder_seq, d), jnp.bfloat16)
            specs["tokens"] = _sds((B, S), jnp.int32)
        elif cfg.family == "vlm":
            specs["patch_embeddings"] = _sds((B, cfg.num_patches, d), jnp.bfloat16)
            specs["tokens"] = _sds((B, S - cfg.num_patches), jnp.int32)
            specs["mrope_positions"] = _sds((3, B, S), jnp.int32)
        else:
            specs["tokens"] = _sds((B, S), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
    else:  # decode
        specs["tokens"] = _sds((B, 1), jnp.int32)
        specs["positions"] = _sds((B,), jnp.int32)
        specs["cache"] = T.init_cache_specs(cfg, B, S)
        if cfg.family == "encdec":
            specs["encoder_output"] = _sds((B, cfg.encoder_seq, d), jnp.bfloat16)
        if cfg.mrope:
            specs["mrope_positions"] = _sds((3, B, 1), jnp.int32)
    return specs


def synthesize_inputs(cfg: ModelConfig, shape: InputShape, key=None) -> dict:
    """Concrete random inputs matching input_specs (smoke tests / examples)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)

    def fill(path, spec):
        if jnp.issubdtype(spec.dtype, jnp.integer):
            hi = cfg.vocab_size if "token" in path or "label" in path else max(
                1, shape.seq_len)
            return jax.random.randint(
                jax.random.fold_in(key, hash(path) % (2**31)), spec.shape, 0,
                min(hi, 2**30), dtype=spec.dtype)
        return jax.random.normal(
            jax.random.fold_in(key, hash(path) % (2**31)), spec.shape,
            dtype=jnp.float32).astype(spec.dtype) * 0.02

    def walk(prefix, tree):
        if isinstance(tree, dict):
            return {k: walk(f"{prefix}/{k}", v) for k, v in tree.items()}
        return fill(prefix, tree)

    return walk("", specs)
