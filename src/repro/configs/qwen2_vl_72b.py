"""qwen2-vl-72b [vlm] — arXiv:2409.12191.

80L, d_model=8192, 64H (GQA kv=8, head_dim=128), d_ff=29568, vocab=152064.
M-RoPE (temporal/height/width sections 16/24/24 of head_dim/2=64);
ViT/projector frontend is a STUB per the brief: ``input_specs`` provides
(B, 256, 8192) patch embeddings (dynamic-resolution budget of 256 tokens).
long_500k runs under the documented sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=29568, vocab_size=152_064,
    mrope=True, mrope_sections=(16, 24, 24), num_patches=256,
    qkv_bias=True, rope_theta=1_000_000.0,
    long_context_window=8192, tie_embeddings=False,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=307,
    mrope=True, mrope_sections=(8, 4, 4), num_patches=8,
    qkv_bias=True, rope_theta=1_000_000.0,
    long_context_window=8192, tie_embeddings=False,
)
