"""gemma2-27b [dense] — arXiv:2408.00118.

46L, d_model=4608, 32H (GQA kv=16), d_ff=36864, vocab=256000.
Local(4096-window)/global alternating attention, attn-logit softcap 50,
final-logit softcap 30, post-block norms, scaled embeddings, GELU.
head_dim = d_model/heads = 144 per the assigned table (DESIGN.md §9).
long_500k qualifies natively via the local/global pattern.
"""
from repro.configs.base import ModelConfig

_COMMON = dict(
    family="dense", local_global_pattern=True, sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norm=True, embed_scale=True, act="gelu",
    tie_embeddings=True,
)

CONFIG = ModelConfig(
    name="gemma2-27b", num_layers=46, d_model=4608, num_heads=32,
    num_kv_heads=16, d_ff=36864, vocab_size=256_000, **_COMMON)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-27b-smoke", num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, d_ff=512, vocab_size=307,
    **{**_COMMON, "sliding_window": 8})
