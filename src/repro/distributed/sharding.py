"""Sharding strategies: parameter/batch/cache PartitionSpecs per strategy.

Strategies (see DESIGN.md §3/§6):
  dp       — paper-faithful Horovod data parallelism: weights REPLICATED,
             batch sharded over every mesh axis.  Only fits sub-HBM models.
  dp_tp    — batch over ('pod','data'), tensor parallelism over 'model'
             (heads / d_ff / experts / vocab).  The minimal extension that
             makes the >=27B archs deployable; weights replicated over data.
  fsdp_tp  — dp_tp plus ZeRO-3-style parameter+optimizer sharding over
             'data' (beyond-paper default for the big archs).

Specs are derived from the *parameter path* + rank: every stacked-layer
leaf carries leading stack dims (scan axes) that are never sharded; the
trailing "physical" dims follow Megatron-style rules (column-parallel in,
row-parallel out), experts shard over 'model' (expert parallelism), vocab
over 'model'.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

STRATEGIES = ("dp", "dp_tp", "fsdp_tp")


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _n_stack(path: str, cfg) -> int:
    """Number of leading scan/stack dims for a param leaf at ``path``."""
    if path.startswith("layers"):
        if cfg.family in ("dense", "vlm", "moe"):
            return 2                       # (groups, pattern, ...)
        if cfg.family == "hybrid":
            return 2                       # (groups, attn_every, ...)
        return 1                           # ssm / encdec decoder: (L, ...)
    if path.startswith("tail_layers"):
        return 1
    if path.startswith("encoder/layers"):
        return 1
    return 0                               # embed, norms, shared_attn, ...


def _trailing_spec(path: str, trailing_rank: int, cfg,
                   fsdp: bool) -> Tuple[Optional[str], ...]:
    """Megatron-style spec for the physical (post-stack) dims."""
    d = "data" if fsdp else None
    leaf = path.split("/")

    def is_(*names):
        return any(n in leaf for n in names)

    # ---- MoE experts: (E, d, f) / (E, f, d) — expert parallel over model --
    if is_("moe"):
        if leaf[-2] in ("wi", "wg") or leaf[-1] in ("wi", "wg"):
            return ("model", d, None)
        if leaf[-2] == "wo" or leaf[-1] == "wo":
            return ("model", None, d)
        if is_("router"):
            return (d, None)
    # ---- attention ---------------------------------------------------------
    if is_("attn", "cross_attn"):
        if leaf[-2] in ("wq", "wk", "wv"):
            if leaf[-1] == "w":            # (d, heads, dh): column parallel
                return (d, "model", None)
            return ("model", None)         # bias (heads, dh)
        if leaf[-2] == "wo":               # (h*dh, d): row parallel
            return ("model", d) if leaf[-1] == "w" else (None,)
    # ---- dense MLP -----------------------------------------------------------
    if is_("mlp"):
        if leaf[-2] in ("wi", "wg"):
            return (d, "model") if leaf[-1] == "w" else ("model",)
        if leaf[-2] == "wo":
            return ("model", d) if leaf[-1] == "w" else (None,)
    # ---- Mamba2 ---------------------------------------------------------------
    if is_("mamba"):
        if leaf[-2] == "in_proj":          # (d, 2di+2GN+H): column parallel
            return (d, "model") if leaf[-1] == "w" else ("model",)
        if leaf[-2] == "out_proj":         # (di, d): row parallel
            return ("model", d) if leaf[-1] == "w" else (None,)
        if leaf[-1] == "conv_w":           # (w, conv_dim)
            return (None, "model")
        if leaf[-1] == "conv_b":
            return ("model",)
        if leaf[-1] in ("dt_bias", "A_log", "D"):   # (H,)
            return ("model",)
        if leaf[-1] == "scale":            # gated-norm scale (di,)
            return ("model",)
    # ---- embeddings / head ------------------------------------------------------
    if leaf[0] == "embed" or leaf[-2:] == ["embed", "table"]:
        return ("model", d)                # vocab over model, d over data
    if leaf[0] == "lm_head":
        return (d, "model")
    if "pos_embed" in leaf:
        return (None, d)
    # ---- norms & everything else: replicate -------------------------------------
    return tuple([None] * trailing_rank)


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the corresponding dim —
    e.g. kv_heads=2 cannot shard over a 16-way 'model' axis."""
    fitted = []
    for i, entry in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            fitted.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        remaining = shape[i]
        for ax in axes:
            n = mesh.shape[ax]
            if remaining % n == 0:
                keep.append(ax)
                remaining //= n
        fitted.append(tuple(keep) if len(keep) > 1 else
                      (keep[0] if keep else None))
    return P(*fitted)


def param_spec(path: str, shape: Tuple[int, ...], cfg, strategy: str,
               mesh: Mesh) -> P:
    if strategy == "dp":
        return P()
    fsdp = strategy == "fsdp_tp"
    ndim = len(shape)
    ns = _n_stack(path, cfg)
    trailing = _trailing_spec(path, ndim - ns, cfg, fsdp)
    spec = (None,) * ns + tuple(trailing)
    spec = (spec + (None,) * ndim)[:ndim]
    return fit_spec(P(*spec), shape, mesh)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def params_pspec(params_struct, cfg, strategy: str, mesh: Mesh):
    """Tree of PartitionSpecs matching a params (or ShapeDtypeStruct) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_struct)
    specs = [param_spec(_path_str(p), tuple(l.shape), cfg, strategy, mesh)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_pspec(opt_state_struct, params_spec_tree):
    """Optimizer-state specs: moment trees mirror the param specs, scalars
    replicate."""
    def per_key(v):
        # a moment tree has the same treedef as params
        if jax.tree_util.tree_structure(v) == jax.tree_util.tree_structure(
                params_spec_tree):
            return params_spec_tree
        return jax.tree.map(lambda _: P(), v)
    return {k: per_key(v) for k, v in opt_state_struct.items()}


# ---------------------------------------------------------------------------
# Batch / cache / activation specs
# ---------------------------------------------------------------------------

def batch_pspec(batch_struct, mesh: Mesh, cfg, shape,
                strategy: str = "dp_tp") -> Any:
    """Input sharding for a train/prefill/decode batch dict.

    Under pure DP (the paper-faithful strategy) every chip is a Horovod
    rank: the batch shards over ALL mesh axes; under *_tp the 'model' axis
    carries tensor parallelism and batch shards over (pod, data) only.
    """
    daxes = all_axes(mesh) if strategy == "dp" else data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    B = shape.global_batch
    batch_shardable = B % dsize == 0 and B >= dsize

    def spec_for(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if p.startswith("cache"):
            # cache leaves: (*stack, B, S, KV, dh) attn | (*stack, B, H, N, P)
            # ssm | (*stack, B, w-1, conv) conv
            is_attn = p.endswith("/k") or p.endswith("/v")
            is_ssm = p.endswith("/ssm")
            stack = nd - (4 if (is_attn or is_ssm) else 3)
            lead = (None,) * stack
            batch_ax = daxes if batch_shardable else None
            if is_attn:
                kv_heads = leaf.shape[-2]
                kv_fits = kv_heads % mesh.shape["model"] == 0
                seq_axes = () if batch_shardable else daxes
                if kv_fits:
                    # heads over model (+ seq over data when B=1:
                    # flash-decoding layout, partial-softmax psum)
                    return P(*lead, batch_ax, seq_axes or None, "model", None)
                # kv heads don't divide the model axis: shard the SEQUENCE
                # over 'model' instead (partial-softmax psum over seq shards)
                seq = tuple(seq_axes) + ("model",)
                return P(*lead, batch_ax, seq if len(seq) > 1 else seq[0],
                         None, None)
            if is_ssm:
                return P(*lead, batch_ax, "model", None, None)
            return P(*lead, batch_ax, None, "model")     # conv state
        if p == "mrope_positions":                    # (3, B, S)
            return P(None, daxes if batch_shardable else None, None)
        # tokens/labels/positions/embeddings: batch-major
        lead = daxes if batch_shardable else None
        return P(*((lead,) + (None,) * (nd - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_struct)
    return jax.tree_util.tree_unflatten(
        treedef, [fit_spec(spec_for(p, l), tuple(l.shape), mesh)
                  for p, l in flat])


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
