from repro.distributed import sharding, stepfn  # noqa: F401
