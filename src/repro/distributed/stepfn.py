"""pjit step builders: train_step / prefill_step / serve_step with explicit
in/out shardings derived from ``repro.distributed.sharding`` strategies.

These are the programs the multi-pod dry-run lowers and the roofline
analysis reads; the same builders drive real training in
``repro.launch.train`` (on whatever mesh exists).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import base as cfgbase
from repro.distributed import sharding as sh
from repro.models import transformer as T


def params_struct(cfg) -> Any:
    """ShapeDtypeStruct pytree of the model params (no allocation)."""
    return jax.eval_shape(
        functools.partial(T.init_params, cfg), jax.random.PRNGKey(0))


def opt_state_struct(cfg, optimizer) -> Any:
    return jax.eval_shape(optimizer.init, params_struct(cfg))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_train_step(cfg, optimizer, mesh: Mesh, strategy: str,
                    shape: cfgbase.InputShape, *, long_context: bool = False,
                    loss_variant: str = "plain", seq_chunk: int = 512,
                    microbatches: int = 1):
    """Returns (jitted_step, in_shardings, out_shardings).

    step(params, opt_state, batch) -> (params, opt_state, metrics).
    loss_variant: "plain" | "chunked_ce" (fused CE without the (B,S,V)
    logits tensor — beyond-paper memory optimization, see §Perf).
    microbatches > 1: gradient accumulation — the global batch is split
    along its leading dim into M microbatches scanned sequentially with
    grad accumulation (activation memory / M, identical update for
    token-mean losses).
    """
    pstruct = params_struct(cfg)
    ostruct = jax.eval_shape(optimizer.init, pstruct)
    bstruct = cfgbase.input_specs(cfg, shape)

    pspec = sh.params_pspec(pstruct, cfg, strategy, mesh)
    ospec = sh.opt_state_pspec(ostruct, pspec)
    bspec = sh.batch_pspec(bstruct, mesh, cfg, shape, strategy)

    in_shardings = (sh.named(mesh, pspec), sh.named(mesh, ospec),
                    sh.named(mesh, bspec))
    out_shardings = (in_shardings[0], in_shardings[1],
                     NamedSharding(mesh, P()))

    def loss_fn(p, b):
        if loss_variant == "chunked_ce":
            return T.lm_loss_chunked(p, cfg, b, long_context=long_context,
                                     seq_chunk=seq_chunk)
        return T.lm_loss(p, cfg, b, long_context=long_context)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            B = batch["tokens"].shape[0]
            assert B % microbatches == 0, (B, microbatches)

            def split(a):
                return a.reshape(microbatches, B // microbatches,
                                 *a.shape[1:])

            micro = jax.tree.map(split, batch)
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, loss_acc = carry
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / microbatches,
                    acc, g)
                return (acc, loss_acc + l / microbatches), m

            (grads, loss), ms = jax.lax.scan(
                body, (zero_grads, jnp.zeros((), jnp.float32)), micro)
            metrics = jax.tree.map(lambda a: jnp.mean(a, axis=0), ms)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, dict(metrics, loss=loss)

    jitted = jax.jit(step, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=(0, 1))
    return jitted, (pstruct, ostruct, bstruct), (in_shardings, out_shardings)


# ---------------------------------------------------------------------------
# Prefill (inference): full-sequence forward, emit ONLY last-token logits
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, mesh: Mesh, strategy: str,
                      shape: cfgbase.InputShape, *,
                      long_context: bool = False):
    pstruct = params_struct(cfg)
    bstruct = cfgbase.input_specs(cfg, shape)
    pspec = sh.params_pspec(pstruct, cfg, strategy, mesh)
    bspec = sh.batch_pspec(bstruct, mesh, cfg, shape, strategy)
    in_shardings = (sh.named(mesh, pspec), sh.named(mesh, bspec))

    def prefill(params, batch):
        logits, _ = T.forward(params, cfg, batch, long_context=long_context,
                              last_only=True)
        return logits                                      # (B, 1, V)

    jitted = jax.jit(prefill, in_shardings=in_shardings)
    return jitted, (pstruct, bstruct), in_shardings


# ---------------------------------------------------------------------------
# Decode (serve_step): ONE token against a seq_len cache
# ---------------------------------------------------------------------------

def make_serve_step(cfg, mesh: Mesh, strategy: str,
                    shape: cfgbase.InputShape, *, long_context: bool = False):
    pstruct = params_struct(cfg)
    bstruct = cfgbase.input_specs(cfg, shape)
    pspec = sh.params_pspec(pstruct, cfg, strategy, mesh)
    bspec = sh.batch_pspec(bstruct, mesh, cfg, shape, strategy)
    in_shardings = (sh.named(mesh, pspec), sh.named(mesh, bspec))
    # new cache keeps the input cache's sharding; logits replicated
    cache_sharding = sh.named(mesh, bspec)["cache"]
    out_shardings = (NamedSharding(mesh, P()), cache_sharding)

    def serve(params, batch):
        logits, new_cache = T.decode_step(params, cfg, batch,
                                          long_context=long_context)
        return logits, new_cache

    # donate the batch so the updated cache aliases the input cache buffers
    jitted = jax.jit(serve, in_shardings=in_shardings,
                     out_shardings=out_shardings,
                     donate_argnums=(1,))
    return jitted, (pstruct, bstruct), in_shardings


def make_step_for_shape(cfg, mesh, strategy, shape, optimizer=None):
    """Dispatch on the shape kind; returns (jitted, arg_structs)."""
    long_context = shape.name == "long_500k"
    if shape.kind == "train":
        optimizer = optimizer or optim.adamw(1e-4)
        jitted, structs, _ = make_train_step(cfg, optimizer, mesh, strategy,
                                             shape, long_context=long_context)
        return jitted, structs
    if shape.kind == "prefill":
        jitted, structs, _ = make_prefill_step(cfg, mesh, strategy, shape,
                                               long_context=long_context)
        return jitted, structs
    jitted, structs, _ = make_serve_step(cfg, mesh, strategy, shape,
                                         long_context=long_context)
    return jitted, structs
