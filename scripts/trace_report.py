#!/usr/bin/env python
"""Offline text report / schema validator for serving trace JSONL files.

The serving stack's tracing is file-based by design (capsules on the
secure cluster cannot host a collector endpoint): the operator copies a
``*.jsonl`` event log out of the allocation and inspects it offline.
This script is the no-GUI half of that workflow — the Chrome trace file
covers the visual half in Perfetto.

Modes
-----
``python scripts/trace_report.py TRACE.jsonl``
    Render a text summary: top stall causes (admission stalls by reason,
    ``out_of_blocks`` by context), per-request critical path (queue wait
    -> time-to-first-token -> decode, with preemption counts), and
    prefill-budget utilization per engine step.  ``--slo`` adds the
    per-tenant SLO section (TTFT / inter-token-gap percentiles derived
    from the events, plus every ``slo_breach``); ``--profile`` adds the
    step-phase timing and ``recompile`` telemetry section; ``--faults``
    adds the failure-handling section (per-replica health transitions,
    failovers with salvage counts, retries, terminal request failures,
    and degradation edges); ``--fleet`` adds the per-replica rollup for
    merged cross-process fabric traces (one stream per worker process,
    clocks per-process monotonic).  ``--json PATH`` additionally writes
    the whole report machine-readable.

    A section with zero matching events is reported as EMPTY with a
    warning (a trace that yields an empty report used to read as a
    healthy run); the exit code stays 0 unless ``--validate`` is given.

``python scripts/trace_report.py --validate TRACE.jsonl [...]``
    CI gate: every line must parse as JSON and satisfy
    :func:`repro.serving.tracing.validate_event` — numeric ``ts``,
    ``kind`` from the documented ``EVENT_KINDS`` enum, integer ``step``
    and/or ``rid``, ``rid`` mandatory for request-scoped kinds.  Also
    fails (exit nonzero) when a core report section — request spans,
    engine steps — or an explicitly requested one (``--slo`` /
    ``--profile`` / ``--faults``) is empty.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter, defaultdict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.serving.metrics import _pct  # noqa: E402
from repro.serving.tracing import EVENT_KINDS, validate_event  # noqa: E402


def load_events(path: Path) -> List[dict]:
    events = []
    with path.open() as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


# ---------------------------------------------------------------------------
# --validate
# ---------------------------------------------------------------------------

def validate_file(path: Path, max_errors: int = 10) -> int:
    """Returns the number of schema violations (prints the first few)."""
    errors = 0
    with path.open() as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errors += 1
                if errors <= max_errors:
                    print(f"{path}:{lineno}: not JSON: {e}")
                continue
            err = validate_event(ev)
            if err is not None:
                errors += 1
                if errors <= max_errors:
                    print(f"{path}:{lineno}: {err}")
    if errors > max_errors:
        print(f"{path}: ... and {errors - max_errors} more violations")
    return errors


# ---------------------------------------------------------------------------
# report sections (each returns a machine-readable dict; "populated"
# means the trace held at least one event the section is made of)
# ---------------------------------------------------------------------------

def _span_key(ev: dict) -> Tuple[str, int]:
    return (ev.get("replica", ""), ev["rid"])


def _fmt_ms(dt: Optional[float]) -> str:
    return f"{dt * 1e3:9.2f}" if dt is not None else "        -"


def _stats_ms(xs: List[float]) -> Dict[str, float]:
    return {"p50": _pct(xs, 0.5) * 1e3, "p95": _pct(xs, 0.95) * 1e3,
            "max": max(xs, default=0.0) * 1e3,
            "mean": sum(xs) / len(xs) * 1e3 if xs else 0.0,
            "count": len(xs)}


def _request_spans(events: List[dict]) -> Dict[Tuple[str, int],
                                               Dict[str, object]]:
    spans: Dict[Tuple[str, int], Dict[str, object]] = defaultdict(dict)
    for ev in events:
        if "rid" not in ev or ev["rid"] < 0:
            continue
        sp = spans[_span_key(ev)]
        k = ev["kind"]
        if k == "submit":
            sp.setdefault(k, ev["ts"])
            sp["tenant"] = ev.get("tenant", "default")
        elif k in ("first_token", "retire"):
            sp.setdefault(k, ev["ts"])
        elif k == "admit":
            # first admission only: a resumed re-admit is not queue wait
            sp.setdefault("admit", ev["ts"])
        elif k == "preempt":
            sp["preempts"] = int(sp.get("preempts", 0)) + 1
        elif k == "decode":
            sp["decodes"] = int(sp.get("decodes", 0)) + 1
            sp.setdefault("decode_ts", []).append(ev["ts"])
        if k == "retire":
            sp["n_tokens"] = ev.get("n_tokens", 0)
            sp["reason"] = ev.get("reason", "?")
    return spans


def stalls_section(events: List[dict], top: int) -> dict:
    stalls: Counter = Counter()
    for ev in events:
        if ev["kind"] == "admission_stall":
            stalls[f"admission_stall:{ev.get('reason', '?')}"] += 1
        elif ev["kind"] == "out_of_blocks":
            stalls[f"out_of_blocks:{ev.get('context', '?')}"] += 1
        elif ev["kind"] == "preempt":
            stalls["preempt" + (":mid_prefill" if ev.get("mid_prefill")
                                else ":decode")] += 1
    print("\n== top stall causes ==")
    if not stalls:
        print("  none recorded")
    for cause, n in stalls.most_common(top):
        print(f"  {n:6d}  {cause}")
    return dict(stalls)


def requests_section(events: List[dict], top: int) -> dict:
    spans = _request_spans(events)

    def total(sp: Dict[str, object]) -> float:
        if "submit" in sp and "retire" in sp:
            return float(sp["retire"]) - float(sp["submit"])  # type: ignore
        return -1.0

    print("\n== per-request critical path (slowest first) ==")
    out = []
    print("  replica/rid       queue ms   ttft ms  decode ms  total ms"
          "  toks  preempts  reason")
    ranked = sorted(spans.items(), key=lambda kv: -total(kv[1]))
    for (replica, rid), sp in ranked:
        sub = sp.get("submit")
        adm = sp.get("admit")
        ft = sp.get("first_token")
        ret = sp.get("retire")
        queue = (adm - sub) if sub is not None and adm is not None else None
        ttft = (ft - sub) if sub is not None and ft is not None else None
        dec = (ret - ft) if ft is not None and ret is not None else None
        tot = (ret - sub) if sub is not None and ret is not None else None
        out.append({"replica": replica, "rid": rid,
                    "tenant": sp.get("tenant", "default"),
                    "queue_ms": queue * 1e3 if queue is not None else None,
                    "ttft_ms": ttft * 1e3 if ttft is not None else None,
                    "total_ms": tot * 1e3 if tot is not None else None,
                    "n_tokens": sp.get("n_tokens"),
                    "preempts": sp.get("preempts", 0),
                    "reason": sp.get("reason")})
    for (replica, rid), sp in ranked[:top]:
        sub, adm = sp.get("submit"), sp.get("admit")
        ft, ret = sp.get("first_token"), sp.get("retire")
        queue = (adm - sub) if sub is not None and adm is not None else None
        ttft = (ft - sub) if sub is not None and ft is not None else None
        dec = (ret - ft) if ft is not None and ret is not None else None
        tot = (ret - sub) if sub is not None and ret is not None else None
        label = f"{replica}/req{rid}" if replica else f"req{rid}"
        print(f"  {label:<16s} {_fmt_ms(queue)} {_fmt_ms(ttft)}"
              f" {_fmt_ms(dec)} {_fmt_ms(tot)}"
              f"  {sp.get('n_tokens', '?'):>4}"
              f"  {sp.get('preempts', 0):>8}"
              f"  {sp.get('reason', '?')}")
    if len(ranked) > top:
        print(f"  ... and {len(ranked) - top} more requests")
    return {"requests": out}


def steps_section(events: List[dict], top: int) -> dict:
    steps = [ev for ev in events if ev["kind"] == "engine_step"]
    budgeted = [ev for ev in steps if ev.get("budget", 0) > 0
                and ev.get("prefill_executed", 0) > 0]
    print("\n== engine steps ==")
    print(f"  {len(steps)} steps recorded, "
          f"{sum(1 for ev in steps if ev.get('decoded'))} decoded, "
          f"{len(budgeted)} ran budgeted prefill")
    data: dict = {"steps": len(steps),
                  "decoded": sum(1 for ev in steps if ev.get("decoded")),
                  "budgeted": len(budgeted)}
    if budgeted:
        utils = [ev["prefill_executed"] / ev["budget"] for ev in budgeted]
        data["budget_utilization_mean"] = sum(utils) / len(utils)
        print(f"  budget utilization: mean {sum(utils) / len(utils):.2f}, "
              f"min {min(utils):.2f}, max {max(utils):.2f} "
              f"(>1.0 = first chunk round of a step always runs whole)")
        print("  step  executed/budget  util   free_blocks  queue  active")
        for ev in budgeted[:top]:
            print(f"  {ev['step']:>4}  {ev['prefill_executed']:>8}/"
                  f"{ev['budget']:<6}  {ev['prefill_executed'] / ev['budget']:4.2f}"
                  f"   {ev.get('free_blocks', '?'):>10}"
                  f"  {ev.get('queue_depth', '?'):>5}"
                  f"  {ev.get('active', '?'):>6}")
        if len(budgeted) > top:
            print(f"  ... and {len(budgeted) - top} more budgeted steps")
    if steps:
        last = steps[-1]
        print(f"  final gauges: free_blocks={last.get('free_blocks', '?')} "
              f"free_slots={last.get('free_slots', '?')} "
              f"queue_depth={last.get('queue_depth', '?')} "
              f"inflight={last.get('inflight', '?')} "
              f"prefix_pins={last.get('prefix_pins', '?')}")
    return data


def slo_section(events: List[dict], top: int) -> dict:
    """Per-tenant TTFT / inter-token gap / queue wait derived from the
    request spans (tenant comes off the ``submit`` events), plus every
    ``slo_breach`` transition in the trace."""
    spans = _request_spans(events)
    per: Dict[str, Dict[str, List[float]]] = defaultdict(
        lambda: {"ttft": [], "gap": [], "queue": [], "requests": []})
    for sp in spans.values():
        tenant = str(sp.get("tenant", "default"))
        per[tenant]["requests"].append(1.0)
        sub, adm, ft = sp.get("submit"), sp.get("admit"), sp.get("first_token")
        if sub is not None and ft is not None:
            per[tenant]["ttft"].append(ft - sub)
        if sub is not None and adm is not None:
            per[tenant]["queue"].append(adm - sub)
        dts = sp.get("decode_ts", [])
        prev = ft
        for ts in dts:
            if prev is not None:
                per[tenant]["gap"].append(ts - prev)
            prev = ts
    breaches = [ev for ev in events if ev["kind"] == "slo_breach"]
    print("\n== SLO (per tenant) ==")
    data: dict = {"tenants": {}, "breaches": []}
    if not per:
        print("  no tenant-labelled requests recorded")
    else:
        print("  tenant            reqs  ttft p50/p95 ms    gap p50/p95 ms"
              "   queue p50/p95 ms")
        for tenant in sorted(per):
            d = per[tenant]
            ttft, gap, q = (_stats_ms(d["ttft"]), _stats_ms(d["gap"]),
                            _stats_ms(d["queue"]))
            data["tenants"][tenant] = {
                "requests": len(d["requests"]),
                "ttft_ms": ttft, "decode_gap_ms": gap, "queue_wait_ms": q}
            print(f"  {tenant:<16s} {len(d['requests']):>5} "
                  f"  {ttft['p50']:7.2f}/{ttft['p95']:<7.2f}"
                  f"   {gap['p50']:6.2f}/{gap['p95']:<7.2f}"
                  f"   {q['p50']:6.2f}/{q['p95']:<7.2f}")
    if breaches:
        print(f"  {len(breaches)} SLO transition(s):")
        for ev in breaches[:top]:
            state = "RECOVERED" if ev.get("recovered") else "BREACH"
            print(f"    step {ev.get('step', '?'):>4}  {state:<9s} "
                  f"{ev.get('tenant', '?')}/{ev.get('metric', '?')}: "
                  f"observed {ev.get('observed', 0.0):.2f} vs "
                  f"threshold {ev.get('threshold', 0.0):.2f}")
        if len(breaches) > top:
            print(f"    ... and {len(breaches) - top} more")
    else:
        print("  no SLO breaches recorded")
    data["breaches"] = [{k: ev.get(k) for k in
                         ("step", "tenant", "metric", "observed",
                          "threshold", "recovered")} for ev in breaches]
    return data


def profile_section(events: List[dict], top: int) -> dict:
    """Step-phase wall percentiles from the ``engine_step`` events plus
    jit ``recompile`` telemetry.  With the scheduler's ``profile=True``
    the phase durations are device time (block_until_ready-bracketed);
    otherwise they measure dispatch."""
    steps = [ev for ev in events if ev["kind"] == "engine_step"]
    print("\n== profile ==")
    data: dict = {"phases": {}, "recompiles": {}}
    if not steps:
        print("  no engine_step events recorded")
    else:
        print("  phase     p50 ms    p95 ms    max ms   total s")
        for phase in ("admit", "prefill", "decode", "sample"):
            durs = [ev.get(f"dur_{phase}_s", 0.0) for ev in steps]
            st = _stats_ms(durs)
            st["total_s"] = sum(durs)
            data["phases"][phase] = st
            print(f"  {phase:<8s} {st['p50']:7.3f}  {st['p95']:8.3f}"
                  f"  {st['max']:8.3f}  {st['total_s']:8.3f}")
    rec = [ev for ev in events if ev["kind"] == "recompile"]
    if rec:
        by_prog: Dict[str, List[dict]] = defaultdict(list)
        for ev in rec:
            by_prog[str(ev.get("program", "?"))].append(ev)
        print(f"  {len(rec)} recompile warning(s) — shape churn:")
        for prog, evs in sorted(by_prog.items()):
            post = sum(1 for e in evs if e.get("post_warm"))
            data["recompiles"][prog] = {"warnings": len(evs),
                                        "post_warm": post}
            print(f"    {prog}: {len(evs)} novel signature(s), "
                  f"{post} post-warm — pad the wobbling dimension")
    else:
        print("  no recompile warnings (stable shapes)")
    return data


def faults_section(events: List[dict], top: int) -> dict:
    """Failure-handling timeline: health transitions, failovers with
    salvage counts, retries and terminal failures per replica, rejoins,
    and degradation (overload) edges."""
    health = [ev for ev in events if ev["kind"] == "replica_health"]
    failovers = [ev for ev in events if ev["kind"] == "replica_failover"]
    retries = [ev for ev in events if ev["kind"] == "replica_retry"]
    rejoins = [ev for ev in events if ev["kind"] == "replica_rejoin"]
    failed = [ev for ev in events if ev["kind"] == "request_failed"]
    overloads = [ev for ev in events if ev["kind"] in ("overload_shed",
                                                       "overload_cap")]
    print("\n== faults / failover ==")
    fault_events = (health + failovers + retries + rejoins + failed
                    + overloads)
    data: dict = {"fault_events": len(fault_events), "replicas": {},
                  "transitions": [], "failed_requests": [],
                  "overload": []}
    if not fault_events:
        print("  no fault-handling events recorded")
        return data
    per: Dict[str, Dict[str, int]] = defaultdict(
        lambda: {"transitions": 0, "failovers": 0, "salvaged": 0,
                 "retries_in": 0, "failed": 0, "rejoins": 0})
    for ev in health:
        per[ev.get("replica", "?")]["transitions"] += 1
    for ev in failovers:
        d = per[ev.get("replica", "?")]
        d["failovers"] += 1
        d["salvaged"] += (ev.get("salvaged_inflight", 0)
                          + ev.get("salvaged_queued", 0))
    for ev in retries:
        # stamped with the replica that *received* the retried request
        per[ev.get("replica", "?")]["retries_in"] += 1
    for ev in rejoins:
        per[ev.get("replica", "?")]["rejoins"] += 1
    for ev in failed:
        per[ev.get("replica", "?")]["failed"] += 1
    print("  replica           transitions  failovers  salvaged  "
          "retries-in  failed  rejoins")
    for name in sorted(per):
        d = per[name]
        data["replicas"][name] = dict(d)
        print(f"  {name:<16s} {d['transitions']:>12} {d['failovers']:>10}"
              f" {d['salvaged']:>9} {d['retries_in']:>11}"
              f" {d['failed']:>7} {d['rejoins']:>8}")
    if health:
        print(f"  {len(health)} health transition(s):")
        for ev in health[:top]:
            print(f"    {ev.get('replica', '?')}: {ev.get('old', '?')} -> "
                  f"{ev.get('new', '?')} ({ev.get('reason', '?')})")
        if len(health) > top:
            print(f"    ... and {len(health) - top} more")
    data["transitions"] = [{k: ev.get(k) for k in
                            ("replica", "old", "new", "reason")}
                           for ev in health]
    for ev in failed[:top]:
        print(f"    FAILED req{ev.get('rid', '?')} on "
              f"{ev.get('replica', '?')}: {ev.get('reason', '?')} after "
              f"{ev.get('attempts', '?')} attempt(s)")
    data["failed_requests"] = [{k: ev.get(k) for k in
                                ("replica", "rid", "reason", "attempts")}
                               for ev in failed]
    for ev in overloads[:top]:
        if ev["kind"] == "overload_shed":
            state = "RECOVERED" if ev.get("recovered") else "DEGRADED"
            print(f"    {state}: {ev.get('reason', '?')} "
                  f"(queue depth {ev.get('queue_depth', '?')})")
        else:
            print(f"    CAPPED req{ev.get('rid', '?')} "
                  f"({ev.get('tenant', '?')}): max_new "
                  f"{ev.get('orig_max_new', '?')} -> "
                  f"{ev.get('capped_max_new', '?')}")
    data["overload"] = [{k: ev.get(k) for k in
                         ("kind", "reason", "recovered", "queue_depth",
                          "tenant", "orig_max_new", "capped_max_new")}
                        for ev in overloads]
    return data


def fleet_section(events: List[dict], top: int) -> dict:
    """Per-replica rollup of a merged cross-process fabric trace: each
    worker exports its own stream (per-process monotonic clock, so spans
    are only meaningful within a replica) and the gateway contributes
    the failover timeline.  One row per replica — events, requests,
    completions, decode steps, failovers and retries received — plus the
    gateway's cross-replica failure counts."""
    per: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"events": 0, "requests": 0, "completed": 0,
                 "engine_steps": 0, "failovers": 0, "retries_in": 0,
                 "health_transitions": 0, "span_ms": 0.0})
    ts_range: Dict[str, List[float]] = defaultdict(list)
    for ev in events:
        name = ev.get("replica", "")
        d = per[name]
        d["events"] += 1
        ts_range[name].append(ev["ts"])
        k = ev["kind"]
        if k == "submit":
            d["requests"] += 1
        elif k == "retire":
            d["completed"] += 1
        elif k == "engine_step":
            d["engine_steps"] += 1
        elif k == "replica_failover":
            d["failovers"] += 1
        elif k == "replica_retry":
            d["retries_in"] += 1
        elif k == "replica_health":
            d["health_transitions"] += 1
    print("\n== fleet (per replica; clocks are per-process) ==")
    data: dict = {"replicas": {}, "failovers": 0, "retries": 0}
    if not per:
        print("  no replica-stamped events recorded")
        return data
    print("  replica           events  reqs  done  steps  failovers  "
          "retries-in  health  span ms")
    for name in sorted(per):
        d = per[name]
        tss = ts_range[name]
        d["span_ms"] = (max(tss) - min(tss)) * 1e3 if tss else 0.0
        data["replicas"][name or "(unstamped)"] = dict(d)
        data["failovers"] += int(d["failovers"])
        data["retries"] += int(d["retries_in"])
        print(f"  {name or '(unstamped)':<16s} {int(d['events']):>7}"
              f" {int(d['requests']):>5} {int(d['completed']):>5}"
              f" {int(d['engine_steps']):>6} {int(d['failovers']):>10}"
              f" {int(d['retries_in']):>11} {int(d['health_transitions']):>7}"
              f" {d['span_ms']:>8.1f}")
    print(f"  fleet: {len(per)} replica stream(s), "
          f"{data['failovers']} failover(s), "
          f"{data['retries']} retried request(s) received")
    return data


def report(events: List[dict], top: int = 10, slo: bool = False,
           profile: bool = False, faults: bool = False,
           fleet: bool = False) -> Tuple[dict, List[str]]:
    """Print the text report; returns ``(machine-readable data, names of
    empty sections)``.  A section is *empty* when the trace held zero of
    the events it is built from — distinct from a healthy zero (e.g. no
    stalls recorded is good news, so stalls never count as empty)."""
    data: dict = {"events": len(events)}
    if not events:
        print("empty trace: no events")
        return data, ["events"]
    t0 = min(ev["ts"] for ev in events)
    kinds = Counter(ev["kind"] for ev in events)
    replicas = sorted({ev.get("replica", "") for ev in events})
    print(f"{len(events)} events, {len(kinds)} kinds, "
          f"replicas: {', '.join(r or '(unstamped)' for r in replicas)}, "
          f"span {(max(ev['ts'] for ev in events) - t0) * 1e3:.1f} ms")
    data["kinds"] = dict(kinds)

    empty: List[str] = []
    data["stalls"] = stalls_section(events, top)
    data["requests"] = requests_section(events, top)
    if not data["requests"]["requests"]:
        empty.append("requests")
    data["engine_steps"] = steps_section(events, top)
    if not data["engine_steps"]["steps"]:
        empty.append("engine_steps")
    if slo:
        data["slo"] = slo_section(events, top)
        if not data["slo"]["tenants"]:
            empty.append("slo")
    if profile:
        data["profile"] = profile_section(events, top)
        if not data["profile"]["phases"]:
            empty.append("profile")
    if faults:
        data["faults"] = faults_section(events, top)
        if not data["faults"]["fault_events"]:
            empty.append("faults")
    if fleet:
        data["fleet"] = fleet_section(events, top)
        if not data["fleet"]["replicas"]:
            empty.append("fleet")
    if empty:
        print(f"\nwarning: empty report section(s): {', '.join(empty)} — "
              "the trace had zero matching events "
              "(fails under --validate)")
    return data, empty


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", type=Path,
                    help="trace JSONL file(s)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check + fail on empty report sections")
    ap.add_argument("--slo", action="store_true",
                    help="add the per-tenant SLO section")
    ap.add_argument("--profile", action="store_true",
                    help="add the step-phase / recompilation section")
    ap.add_argument("--faults", action="store_true",
                    help="add the failure-handling section (health "
                         "transitions, failovers, retries, overload)")
    ap.add_argument("--fleet", action="store_true",
                    help="add the per-replica fleet section for merged "
                         "cross-process fabric traces")
    ap.add_argument("--json", type=Path, default=None, metavar="PATH",
                    help="also write the report machine-readable")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per report section (default 10)")
    args = ap.parse_args(argv)

    bad = 0
    all_data: Dict[str, dict] = {}
    for path in args.traces:
        if len(args.traces) > 1 or args.validate:
            print(f"\n### {path}")
        if args.validate:
            n_events = sum(1 for line in path.open() if line.strip())
            errors = validate_file(path)
            bad += errors
            status = "OK" if errors == 0 else f"{errors} violations"
            print(f"{path}: {n_events} events, "
                  f"{len(EVENT_KINDS)} known kinds: {status}")
        data, empty = report(load_events(path), top=args.top,
                             slo=args.slo, profile=args.profile,
                             faults=args.faults, fleet=args.fleet)
        all_data[str(path)] = data
        if args.validate and empty:
            print(f"{path}: FAIL — empty section(s): {', '.join(empty)}")
            bad += len(empty)
    if args.json is not None:
        payload = (next(iter(all_data.values()))
                   if len(all_data) == 1 else all_data)
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(payload, indent=2, sort_keys=True,
                                        default=str) + "\n")
        print(f"\nwrote {args.json}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
