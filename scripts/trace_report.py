#!/usr/bin/env python
"""Offline text report / schema validator for serving trace JSONL files.

The serving stack's tracing is file-based by design (capsules on the
secure cluster cannot host a collector endpoint): the operator copies a
``*.jsonl`` event log out of the allocation and inspects it offline.
This script is the no-GUI half of that workflow — the Chrome trace file
covers the visual half in Perfetto.

Modes
-----
``python scripts/trace_report.py TRACE.jsonl``
    Render a text summary: top stall causes (admission stalls by reason,
    ``out_of_blocks`` by context), per-request critical path (queue wait
    -> time-to-first-token -> decode, with preemption counts), and
    prefill-budget utilization per engine step.

``python scripts/trace_report.py --validate TRACE.jsonl [...]``
    Schema check used by CI: every line must parse as JSON and satisfy
    :func:`repro.serving.tracing.validate_event` — numeric ``ts``,
    ``kind`` from the documented ``EVENT_KINDS`` enum, integer ``step``
    and/or ``rid``, ``rid`` mandatory for request-scoped kinds.  Exits
    nonzero on the first file with violations.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter, defaultdict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.serving.tracing import EVENT_KINDS, validate_event  # noqa: E402


def load_events(path: Path) -> List[dict]:
    events = []
    with path.open() as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


# ---------------------------------------------------------------------------
# --validate
# ---------------------------------------------------------------------------

def validate_file(path: Path, max_errors: int = 10) -> int:
    """Returns the number of schema violations (prints the first few)."""
    errors = 0
    with path.open() as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errors += 1
                if errors <= max_errors:
                    print(f"{path}:{lineno}: not JSON: {e}")
                continue
            err = validate_event(ev)
            if err is not None:
                errors += 1
                if errors <= max_errors:
                    print(f"{path}:{lineno}: {err}")
    if errors > max_errors:
        print(f"{path}: ... and {errors - max_errors} more violations")
    return errors


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def _span_key(ev: dict) -> Tuple[str, int]:
    return (ev.get("replica", ""), ev["rid"])


def _fmt_ms(dt: Optional[float]) -> str:
    return f"{dt * 1e3:9.2f}" if dt is not None else "        -"


def report(events: List[dict], top: int = 10) -> None:
    if not events:
        print("empty trace: no events")
        return
    t0 = min(ev["ts"] for ev in events)
    kinds = Counter(ev["kind"] for ev in events)
    replicas = sorted({ev.get("replica", "") for ev in events})
    print(f"{len(events)} events, {len(kinds)} kinds, "
          f"replicas: {', '.join(r or '(unstamped)' for r in replicas)}, "
          f"span {(max(ev['ts'] for ev in events) - t0) * 1e3:.1f} ms")

    # -- top stall causes ---------------------------------------------------
    stalls: Counter = Counter()
    for ev in events:
        if ev["kind"] == "admission_stall":
            stalls[f"admission_stall:{ev.get('reason', '?')}"] += 1
        elif ev["kind"] == "out_of_blocks":
            stalls[f"out_of_blocks:{ev.get('context', '?')}"] += 1
        elif ev["kind"] == "preempt":
            stalls["preempt" + (":mid_prefill" if ev.get("mid_prefill")
                                else ":decode")] += 1
    print("\n== top stall causes ==")
    if not stalls:
        print("  none recorded")
    for cause, n in stalls.most_common(top):
        print(f"  {n:6d}  {cause}")

    # -- per-request critical path ------------------------------------------
    spans: Dict[Tuple[str, int], Dict[str, object]] = defaultdict(dict)
    for ev in events:
        if "rid" not in ev or ev["rid"] < 0:
            continue
        sp = spans[_span_key(ev)]
        k = ev["kind"]
        if k in ("submit", "first_token", "retire"):
            sp.setdefault(k, ev["ts"])
        elif k == "admit":
            # first admission only: a resumed re-admit is not queue wait
            sp.setdefault("admit", ev["ts"])
        elif k == "preempt":
            sp["preempts"] = int(sp.get("preempts", 0)) + 1
        elif k == "decode":
            sp["decodes"] = int(sp.get("decodes", 0)) + 1
        if k == "retire":
            sp["n_tokens"] = ev.get("n_tokens", 0)
            sp["reason"] = ev.get("reason", "?")

    def total(sp: Dict[str, object]) -> float:
        if "submit" in sp and "retire" in sp:
            return float(sp["retire"]) - float(sp["submit"])  # type: ignore
        return -1.0

    print("\n== per-request critical path (slowest first) ==")
    print("  replica/rid       queue ms   ttft ms  decode ms  total ms"
          "  toks  preempts  reason")
    ranked = sorted(spans.items(), key=lambda kv: -total(kv[1]))
    for (replica, rid), sp in ranked[:top]:
        sub = sp.get("submit")
        adm = sp.get("admit")
        ft = sp.get("first_token")
        ret = sp.get("retire")
        queue = (adm - sub) if sub is not None and adm is not None else None
        ttft = (ft - sub) if sub is not None and ft is not None else None
        dec = (ret - ft) if ft is not None and ret is not None else None
        tot = (ret - sub) if sub is not None and ret is not None else None
        label = f"{replica}/req{rid}" if replica else f"req{rid}"
        print(f"  {label:<16s} {_fmt_ms(queue)} {_fmt_ms(ttft)}"
              f" {_fmt_ms(dec)} {_fmt_ms(tot)}"
              f"  {sp.get('n_tokens', '?'):>4}"
              f"  {sp.get('preempts', 0):>8}"
              f"  {sp.get('reason', '?')}")
    if len(ranked) > top:
        print(f"  ... and {len(ranked) - top} more requests")

    # -- budget utilization per step ----------------------------------------
    steps = [ev for ev in events if ev["kind"] == "engine_step"]
    budgeted = [ev for ev in steps if ev.get("budget", 0) > 0
                and ev.get("prefill_executed", 0) > 0]
    print("\n== engine steps ==")
    print(f"  {len(steps)} steps recorded, "
          f"{sum(1 for ev in steps if ev.get('decoded'))} decoded, "
          f"{len(budgeted)} ran budgeted prefill")
    if budgeted:
        utils = [ev["prefill_executed"] / ev["budget"] for ev in budgeted]
        print(f"  budget utilization: mean {sum(utils) / len(utils):.2f}, "
              f"min {min(utils):.2f}, max {max(utils):.2f} "
              f"(>1.0 = first chunk round of a step always runs whole)")
        print("  step  executed/budget  util   free_blocks  queue  active")
        for ev in budgeted[:top]:
            print(f"  {ev['step']:>4}  {ev['prefill_executed']:>8}/"
                  f"{ev['budget']:<6}  {ev['prefill_executed'] / ev['budget']:4.2f}"
                  f"   {ev.get('free_blocks', '?'):>10}"
                  f"  {ev.get('queue_depth', '?'):>5}"
                  f"  {ev.get('active', '?'):>6}")
        if len(budgeted) > top:
            print(f"  ... and {len(budgeted) - top} more budgeted steps")
    if steps:
        last = steps[-1]
        print(f"  final gauges: free_blocks={last.get('free_blocks', '?')} "
              f"free_slots={last.get('free_slots', '?')} "
              f"queue_depth={last.get('queue_depth', '?')} "
              f"inflight={last.get('inflight', '?')} "
              f"prefix_pins={last.get('prefix_pins', '?')}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", type=Path,
                    help="trace JSONL file(s)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only; exit nonzero on violations")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per report section (default 10)")
    args = ap.parse_args(argv)

    if args.validate:
        bad = 0
        for path in args.traces:
            n_events = sum(1 for line in path.open() if line.strip())
            errors = validate_file(path)
            bad += errors
            status = "OK" if errors == 0 else f"{errors} violations"
            print(f"{path}: {n_events} events, "
                  f"{len(EVENT_KINDS)} known kinds: {status}")
        return 1 if bad else 0

    for path in args.traces:
        if len(args.traces) > 1:
            print(f"\n### {path}")
        report(load_events(path), top=args.top)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
