#!/usr/bin/env sh
# One-invocation verify recipe: the repo's tier-1 test command (ROADMAP.md).
# Usage: scripts/ci.sh [extra pytest args]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
