#!/usr/bin/env sh
# One-invocation verify recipe: the repo's tier-1 test command (ROADMAP.md),
# then fast smokes of the prefix-cache benchmark (cold/warm TTFT + the
# bit-identity assertion inside it), the paged-attention benchmark
# (paged > dense concurrency at equal KV bytes, undersized-pool run with
# no drops / no leaked pins, greedy bit-identity — each is asserted), and
# the batched-prefill and interleaved-prefill benchmarks via
# `benchmarks.run --check`, which also validates every emitted
# BENCH_*.json artifact (bit_identical_outputs true where present,
# nonzero completed requests) so a silently-broken benchmark fails the
# build.  The tracing benchmark (quick mode) asserts enabled-tracing
# wall clock within 5% of disabled and emits results/trace_sample.jsonl,
# which trace_report.py --validate then schema-checks (every event: ts,
# kind from the documented enum, step and/or rid) and renders with the
# SLO + profile sections, failing on any empty one.  The slo benchmark
# asserts the full observatory (per-tenant SLO monitor + step profiler +
# recompile tracker) stays within the same 5% budget, bit-identical,
# with zero post-warm recompilations.
# Usage: scripts/ci.sh [extra pytest args]
# CI runs the full suite (including the slow-marked interleaved
# scheduler stress sweep); pass `-m "not slow"` for the quick tier.
set -e
cd "$(dirname "$0")/.."
# static gate first: repro-lint (src/repro/analysis) fails the build on
# any finding not in scripts/lint_baseline.json — hot-path syncs,
# recompile hazards, Pallas launch bugs, tracing-schema drift, and
# leak-shaped lifecycles are cheaper to catch before anything runs
python scripts/lint.py src benchmarks
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# invoked directly (not via benchmarks.run) so a failure fails the build
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.prefix_cache
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.paged_attention
# --check exits nonzero on a FAILED row or an unhealthy BENCH_*.json;
# fault_tolerance kills 1 of 3 replicas mid-burst and asserts every
# salvaged request completes bit-identical (salvage rate gated by
# _check_faults on BENCH_faults.json); fabric repeats the claim across
# real process boundaries — 3 subprocess workers over the mailbox
# transport, one SIGKILLed mid-burst (same gate, BENCH_fabric.json)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
    --only batched_prefill,interleaved,tracing,slo,fault_tolerance,fabric \
    --check
# trace JSONL schema + report gate on the sample the tracing benchmark
# just wrote: every event validates AND no report section (including the
# requested SLO/profile ones) is empty
python scripts/trace_report.py --slo --profile --validate \
    results/trace_sample.jsonl
# same gate on the fault-tolerance trace: the failure-handling section
# (health transitions, failovers, retries) must be populated
python scripts/trace_report.py --faults --validate \
    results/trace_faults.jsonl
# fleet gate on the merged cross-process fabric trace: per-replica
# worker streams plus the gateway's failover timeline must be populated
python scripts/trace_report.py --fleet --validate \
    results/trace_fabric.jsonl
