#!/usr/bin/env sh
# One-invocation verify recipe: the repo's tier-1 test command (ROADMAP.md),
# then a fast smoke of the prefix-cache benchmark (cold/warm TTFT + the
# bit-identity assertion inside it).
# Usage: scripts/ci.sh [extra pytest args]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# invoked directly (not via benchmarks.run) so a failure fails the build
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.prefix_cache
