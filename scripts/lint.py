#!/usr/bin/env python
"""repro-lint CLI — the build gate scripts/ci.sh runs.

Usage:
    python scripts/lint.py src benchmarks            # gate: exit 1 on new
    python scripts/lint.py --format json src         # machine-readable
    python scripts/lint.py --fix-baseline src benchmarks
    python scripts/lint.py --list-rules

Findings already recorded in the committed baseline
(scripts/lint_baseline.json) are reported as warnings and do not fail
the run; anything new exits nonzero.  ``--fix-baseline`` regenerates the
baseline from the current tree — a deliberate act, reviewed like any
other diff.  See src/repro/analysis/README.md.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import all_rules, lint_paths          # noqa: E402
from repro.analysis import baseline as bl                  # noqa: E402
from repro.analysis.reporters import (render_json,         # noqa: E402
                                      render_text)

DEFAULT_BASELINE = REPO_ROOT / "scripts" / "lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX/Pallas-aware static analysis for this repo")
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline file (default: scripts/"
                         "lint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: every finding fails")
    ap.add_argument("--fix-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "and exit 0")
    ap.add_argument("--rules", help="comma-separated rule ids to run "
                                    "(default: all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.rule_id}  {r.name}: {r.description}")
        return 0
    if not args.paths:
        ap.error("no paths given (try: src benchmarks)")
    if args.rules:
        want = {r.strip() for r in args.rules.split(",")}
        unknown = want - {r.rule_id for r in rules}
        if unknown:
            ap.error(f"unknown rule id(s): {', '.join(sorted(unknown))}")
        rules = [r for r in rules if r.rule_id in want]

    result = lint_paths(args.paths, root=REPO_ROOT, rules=rules)

    if args.fix_baseline:
        bl.save(args.baseline, result.findings, result.modules)
        print(f"repro-lint: baseline rewritten with "
              f"{len(result.findings)} finding(s) -> {args.baseline}")
        return 0

    base = [] if args.no_baseline else bl.load(args.baseline)
    new, old, stale = bl.split(result.findings, base, result.modules)

    if args.format == "json":
        print(render_json(new, old, result.suppressed, len(stale)))
    else:
        print(render_text(new, old, len(result.suppressed), len(stale)))
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
