"""The paper's deployment story, blow by blow (§II-A, §III-B, §IV).

Demonstrates every failure mode the paper describes and the capsule fix:
  1. shared-Python dependency breakage (TensorFlow-then-Caffe),
  2. pip-install-on-the-cluster dying (air gap),
  3. Docker/Singularity refused by site security policy,
  4. the Charliecloud build->flatten->transfer->unpack->run path succeeding,
  5. single-node and multi-node Slurm scripts (§IV-B/C).

Run:  PYTHONPATH=src python examples/deploy_supermuc.py
"""
import tempfile
from pathlib import Path

from repro.core import container as C
from repro.core import deploy as D
from repro.core import registry as R


def main():
    idx = R.default_index()

    print("== 1. the shared-Python failure (paper §II-A) ==")
    env = R.SharedEnvironment(idx)
    env.pip_install("tensorflow==1.11.0")
    print("  installed tensorflow 1.11:", not env.check())
    env.pip_install("caffe==1.0.0")
    for root, problems in env.check().items():
        print(f"  BROKEN {root}: {problems}")

    print("\n== 2. pip install on the cluster dies (air gap) ==")
    try:
        C.CLUSTER.require_internet("pip install tensorflow")
    except R.OfflineViolation as e:
        print("  OfflineViolation:", e)

    print("\n== 3. site security policy (paper §II-C..F) ==")
    pol = C.SecurityPolicy()
    for rt in ("docker", "singularity", "shifter", "charliecloud"):
        try:
            pol.admit(C.RUNTIME_PROFILES[rt])
            print(f"  {rt}: ADMITTED")
        except C.SecurityError as e:
            print(f"  {rt}: refused — {e}")

    print("\n== 4. the Charliecloud path (paper §III-B) ==")
    with tempfile.TemporaryDirectory() as td:
        pipe = D.DeploymentPipeline(index=idx)
        dep = pipe.deploy(D.intel_tensorflow_image(), Path(td),
                          nodes=32, ranks_per_node=1)
        for line in dep.log:
            print("  ", line)
        res = dep.run(lambda: "hello from inside the capsule", ranks=2)
        print("   ch-run:", res[0].value, f"[uid_map {res[0].uid_map}]")

        print("\n== 5. Slurm submission (§IV-C, 32 nodes) ==")
        print("\n".join("   " + l for l in dep.slurm_script.splitlines()))


if __name__ == "__main__":
    main()
