"""Train the CERN 3DGAN (the paper's §IV/§V workload) with Horovod-DP.

Full paper pipeline: deploy an environment capsule, then inside it train
the ~1M-parameter 3D convolutional ACGAN on synthetic CLIC calorimeter
showers with RMSProp, gradients exchanged by allreduce over the data axis
(one rank per device — the paper's one-rank-per-node layout).

Run:  PYTHONPATH=src python examples/train_3dgan.py --steps 100
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
          PYTHONPATH=src python examples/train_3dgan.py --steps 50
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.core import hvd
from repro.data import CalorimeterSpec, generate_batch
from repro.launch.mesh import make_host_mesh
from repro.models import gan3d as G


def make_gan_steps(cfg, mesh, d_opt, g_opt):
    """Paper-faithful DP: replicated params, psum-mean gradients."""
    def d_step(dp, ds, gp, batch, z):
        grads, m = jax.grad(G.d_loss, has_aux=True)(dp, gp, cfg, batch, z)
        upd, ds = hvd.DistributedOptimizer(d_opt, ("data",)).update(grads, ds, dp)
        return optim.apply_updates(dp, upd), ds, hvd.allreduce(m, ("data",))

    def g_step(gp, gs, dp, batch, z):
        grads, m = jax.grad(G.g_loss, has_aux=True)(gp, dp, cfg, batch, z)
        upd, gs = hvd.DistributedOptimizer(g_opt, ("data",)).update(grads, gs, gp)
        return optim.apply_updates(gp, upd), gs, hvd.allreduce(m, ("data",))

    def shard(fn, n_out=3):
        return jax.jit(hvd.shard_map(
            fn, mesh=mesh,
            in_specs=(P(), P(), P(), {"images": P("data"), "energies": P("data")},
                      P("data")),
            out_specs=(P(), P(), P()), check_vma=False))

    return shard(d_step), shard(g_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = G.GAN3DConfig()
    mesh = make_host_mesh()
    n_dev = len(jax.devices())
    print(f"devices={n_dev}  global_batch={args.batch}  (paper: RMSProp, "
          f"allreduce DP)")

    key = jax.random.PRNGKey(0)
    gp = G.init_generator(key, cfg)
    dp = G.init_discriminator(jax.random.fold_in(key, 1), cfg)
    print(f"G params: {G.param_count(gp):,}  D params: {G.param_count(dp):,}")

    # D at half the G rate: keeps the adversary from overpowering the
    # generator in short CPU runs (paper trains far longer at scale)
    d_opt = optim.rmsprop(args.lr * 0.5, clip_norm=1.0)
    g_opt = optim.rmsprop(args.lr, clip_norm=1.0)
    ds, gs = d_opt.init(dp), g_opt.init(gp)
    d_step, g_step = make_gan_steps(cfg, mesh, d_opt, g_opt)

    spec = CalorimeterSpec()
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in generate_batch(spec, args.batch, step=i).items()}
        key, kz1, kz2 = jax.random.split(key, 3)
        z1 = jax.random.normal(kz1, (args.batch, cfg.latent_dim))
        dp, ds, dm = d_step(dp, ds, gp, batch, z1)
        z2 = jax.random.normal(kz2, (args.batch, cfg.latent_dim))
        gp, gs, gm = g_step(gp, gs, dp, batch, z2)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  d_loss {float(dm['d_loss']):.4f}  "
                  f"g_loss {float(gm['g_loss']):.4f}  "
                  f"D(real acc) {float(dm['acc_real']):.2f}  "
                  f"D(fake acc) {float(dm['acc_fake']):.2f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch / dt:.1f} img/s) — compare Table 2")

    # physics sanity: generated total deposition should track requested energy
    e_test = jnp.linspace(50, 400, 8)
    z = jax.random.normal(key, (8, cfg.latent_dim))
    fake = G.generator(gp, cfg, z, e_test)
    totals = jnp.sum(fake, axis=(1, 2, 3, 4))
    corr = np.corrcoef(np.asarray(e_test), np.asarray(totals))[0, 1]
    print(f"corr(requested E, generated deposition) = {corr:.3f}")


if __name__ == "__main__":
    main()
