"""End-to-end LM training driver: ~100M-parameter model, a few hundred steps.

The framework's "real training job": sharded data pipeline -> pjit train
step (dp / dp_tp / fsdp_tp on whatever mesh exists) -> checkpointing with
rotation + restart -> metrics.  This is the same ``stepfn.make_train_step``
program the multi-pod dry-run lowers for the 40 (arch x shape) pairs, here
executed for real on host devices.

Run (fast demo):     PYTHONPATH=src python examples/train_lm.py --steps 30
Run (100M driver):   PYTHONPATH=src python examples/train_lm.py \\
                        --model 100m --steps 300 --seq-len 256 --batch 8
"""
import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ck
from repro import optim
from repro.configs import get_smoke_config
from repro.configs.base import InputShape, ModelConfig
from repro.data import SyntheticTokenSource, TokenDatasetSpec
from repro.distributed import stepfn
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T

MODELS = {
    # ~100M dense LM (embed 20.5M + 10 x 6.5M layers)
    "100m": ModelConfig(
        name="repro-100m", family="dense", num_layers=10, d_model=640,
        num_heads=10, num_kv_heads=5, d_ff=2560, vocab_size=32_000,
        tie_embeddings=True),
    "20m": ModelConfig(
        name="repro-20m", family="dense", num_layers=6, d_model=320,
        num_heads=8, num_kv_heads=4, d_ff=1280, vocab_size=32_000,
        tie_embeddings=True),
    # small vocab => learnable within a CI-sized token budget
    "tiny": ModelConfig(
        name="repro-tiny", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=512,
        tie_embeddings=True),
    "smoke": get_smoke_config("qwen2-0.5b"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="smoke", choices=sorted(MODELS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = MODELS[args.model]
    mesh = make_host_mesh()
    shape = InputShape("train", args.seq_len, args.batch, "train")
    warmup = max(2, min(20, args.steps // 4))
    opt = optim.adamw(
        optim.schedules.warmup_cosine(args.lr, warmup, args.steps),
        weight_decay=0.01, clip_norm=1.0)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model={cfg.name}  params={n_params/1e6:.1f}M  "
          f"devices={len(jax.devices())}  batch={args.batch}x{args.seq_len}")

    opt_state = opt.init(params)
    start = 0
    if args.resume and ck.latest_step(args.ckpt_dir) is not None:
        start = ck.latest_step(args.ckpt_dir)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            {"params": params, "opt": opt_state})
        restored = ck.restore(args.ckpt_dir, like)
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    step_fn, _, _ = stepfn.make_train_step(cfg, opt, mesh, "dp", shape)
    source = SyntheticTokenSource(TokenDatasetSpec(
        cfg.vocab_size, args.seq_len, args.batch))

    losses, t0 = [], time.time()
    tokens_per_step = args.batch * args.seq_len
    for i in range(start, start + args.steps):
        batch = {k: jnp.asarray(v) for k, v in source.batch(i).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if i % 10 == 0 or i == start + args.steps - 1:
            dt = time.time() - t0
            tps = tokens_per_step * (i - start + 1) / dt
            print(f"step {i:4d}  loss {losses[-1]:.4f}  ppl "
                  f"{float(m['perplexity']):.1f}  {tps:,.0f} tok/s")
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            p = ck.save(args.ckpt_dir, i + 1,
                        {"params": params, "opt": opt_state})
            print(f"  checkpoint -> {p}")

    final = min(losses[-3:]) if len(losses) >= 3 else losses[-1]
    print(f"\nloss {losses[0]:.4f} -> {final:.4f} over {args.steps} steps"
          f" ({'DOWN' if final < losses[0] else 'NOT DOWN'})")
    assert final < losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
