"""Quickstart: the paper's workflow end-to-end in two minutes.

1. Build an environment capsule on the "workstation" (deps resolved against
   the offline index — the cluster never touches the network).
2. Deploy it through the Charliecloud-style pipeline (flatten -> transfer ->
   unpack) and render the Slurm script.
3. Inside the capsule, train a small LM for a few steps with the
   paper-faithful Horovod-DP engine and show the loss going down.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro import optim
from repro.configs import get_smoke_config
from repro.core import deploy as D
from repro.core import hvd
from repro.data import SyntheticTokenSource, TokenDatasetSpec
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def train_inside_capsule(steps: int = 20):
    cfg = get_smoke_config("qwen2-0.5b")
    mesh = make_host_mesh()
    n_dev = len(jax.devices())
    spec = TokenDatasetSpec(vocab_size=cfg.vocab_size, seq_len=128,
                            global_batch=max(8, n_dev))
    source = SyntheticTokenSource(spec)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.rmsprop(2e-3, clip_norm=1.0)
    opt_state = opt.init(params)
    step = hvd.make_train_step(lambda p, b: T.lm_loss(p, cfg, b), opt, mesh)
    losses = []
    for i in range(steps):
        batch = {k: jax.numpy.asarray(v) for k, v in source.batch(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if i % 5 == 0:
            print(f"  step {i:3d}  loss {losses[-1]:.4f}")
    print(f"  loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'DOWN' if losses[-1] < losses[0] else 'up?!'})")
    return losses


def main():
    print("== 1. build + deploy the capsule (paper §III-B) ==")
    with tempfile.TemporaryDirectory() as td:
        pipe = D.DeploymentPipeline()
        dep = pipe.deploy(D.intel_tensorflow_image("quickstart"),
                          Path(td), nodes=4)
        for line in dep.log:
            print("  ", line)
        print("\n== 2. the generated Slurm submission (paper §IV-C) ==")
        print("  ", dep.slurm_script.splitlines()[-1])
        print("\n== 3. Horovod-DP training inside the capsule ==")
        results = dep.run(train_inside_capsule, ranks=1)
        print(f"\ncapsule run complete: image={results[0].image} "
              f"uid_map='{results[0].uid_map}' "
              f"wall={results[0].wall_time_s:.1f}s")


if __name__ == "__main__":
    main()
