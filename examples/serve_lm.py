"""Batched serving example: the ``serve_step`` program from the dry-run,
executed for real through the continuous-batching scheduler
(``engine.generate`` routes each request through per-slot prefill, the
paged KV pool, and per-request sampling — see ``repro/serving/``).

Two modes:

* default — a burst of independent random-prompt requests (continuous
  batching demo);
* ``--chat`` — a multi-turn conversation replaying a shared system
  prompt: every turn's prompt is system + history + new user tokens, so
  the prefix-cache subsystem serves the conversation so far from its KV
  store and only the new tail runs through prefill.  Prints per-turn
  recompute counts and the final hit rate.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
      PYTHONPATH=src python examples/serve_lm.py --chat --turns 6
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.models import transformer as T
from repro.serving import Request, SamplingParams, Scheduler, ServingEngine


def run_burst(engine, cfg, args):
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, rng.integers(4, 12),
                                 dtype=np.int32).astype(np.int32),
                    SamplingParams(max_new_tokens=args.max_new,
                                   temperature=0.8))
            for _ in range(args.slots)]
    print(f"batch={len(reqs)} requests")
    t0 = time.time()
    outs = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"  req {i}: prompt_len={len(reqs[i].prompt)} -> {o.tolist()}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s batched decode)")


def run_chat(engine, cfg, args):
    """Multi-turn chat against one engine: shared system prompt + growing
    history, each turn admitted as a full independent prompt — exactly the
    traffic shape the prefix cache exists for."""
    rng = np.random.default_rng(0)
    sched = Scheduler(engine)
    system = rng.integers(0, cfg.vocab_size, args.system_len,
                          dtype=np.int32)
    history = system
    print(f"chat: {args.turns} turns over a shared {len(system)}-token "
          f"system prompt (prefix cache "
          f"{'on' if engine.prefix_cache else 'off'})")
    for turn in range(args.turns):
        user = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9)),
                            dtype=np.int32)
        prompt = np.concatenate([history, user])
        before = engine.prefill_tokens
        t0 = time.time()
        rid = sched.submit(Request(prompt, SamplingParams(
            max_new_tokens=args.max_new, greedy=True)))
        sched.run()
        reply = sched.output(rid)
        ttft_ms = (sched.metrics._first[rid]
                   - sched.metrics._submit[rid]) * 1e3
        print(f"  turn {turn}: prompt {len(prompt):4d} tok, "
              f"recomputed {engine.prefill_tokens - before:3d}, "
              f"ttft {ttft_ms:6.1f} ms -> {reply.tolist()}")
        history = np.concatenate([prompt, reply])
    pc = sched.metrics.summary()["prefix_cache"]
    print(f"prefix cache: hit rate {pc['hit_rate']:.2f}, "
          f"{pc['cached_tokens_served']}/{pc['prompt_tokens']} prompt "
          f"tokens served from cache "
          f"({pc['cached_token_fraction']:.0%}), "
          f"{pc['evictions']} blocks evicted")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chat", action="store_true",
                    help="multi-turn shared-prefix chat demo")
    ap.add_argument("--turns", type=int, default=5)
    ap.add_argument("--system-len", type=int, default=96)
    ap.add_argument("--prefix-blocks", type=int, default=128)
    ap.add_argument("--no-prefix-cache", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serving demo targets decoder LMs; pick another arch")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, max_seq_len=1024 if args.chat else 128,
        max_slots=args.slots,
        prefix_cache_blocks=(0 if args.no_prefix_cache
                             else args.prefix_blocks))
    print(f"arch={args.arch} (smoke variant, family={cfg.family})")
    if args.chat:
        run_chat(engine, cfg, args)
    else:
        run_burst(engine, cfg, args)


if __name__ == "__main__":
    main()
