"""Batched serving example: the ``serve_step`` program from the dry-run,
executed for real through the continuous-batching scheduler
(``engine.generate`` routes each request through per-slot prefill, the
paged KV pool, and per-request sampling — see ``repro/serving/``).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.models import transformer as T
from repro.serving import Request, SamplingParams, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serving demo targets decoder LMs; pick another arch")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_seq_len=128, max_slots=args.slots)

    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, rng.integers(4, 12),
                                 dtype=np.int32).astype(np.int32),
                    SamplingParams(max_new_tokens=args.max_new,
                                   temperature=0.8))
            for _ in range(args.slots)]
    print(f"arch={args.arch} (smoke variant, family={cfg.family})  "
          f"batch={len(reqs)} requests")
    t0 = time.time()
    outs = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"  req {i}: prompt_len={len(reqs[i].prompt)} -> {o.tolist()}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s batched decode)")


if __name__ == "__main__":
    main()
